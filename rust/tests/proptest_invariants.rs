//! Property-based tests (randomized, seeded — in-repo substitute for the
//! proptest crate, which the offline registry cannot supply because it
//! depends on `rand`): coordinator invariants over random graphs and
//! configurations. No artifacts/PJRT required.

use lmc::backend::gemm::{self, Kernels};
use lmc::backend::simd::{self, SimdLevel};
use lmc::backend::{Executor, ModelSpec, NativeExecutor, StepInputs, StepWorkspace};
use lmc::coordinator::params::{grad_rel_err, Params};
use lmc::serve::{plan_tiles, ServeEngine, ServeMode, ServeOptions};
use lmc::graph::{gcn_normalize, load, random_graph, Csr, DatasetId, Graph};
use lmc::config::RunConfig;
use lmc::coordinator::{Method, Trainer};
use lmc::history::{bf16_from_f32, bf16_to_f32, f16_from_f32, f16_to_f32, HistDtype, History};
use lmc::partition::{edge_cut, partition, quality::quality, shard_views, PartitionConfig};
use lmc::runtime::ArchInfo;
use lmc::sampler::{
    beta_vector, build_subgraph, AdjacencyPolicy, Batcher, BatcherMode, BetaScore, Buckets,
    CsrBlock, HaloSampler, HaloSamplerKind,
};
use lmc::util::rng::Rng;

fn random_cases(n_cases: usize) -> impl Iterator<Item = (u64, Csr)> {
    (0..n_cases as u64).map(|seed| {
        let mut rng = Rng::new(seed * 77 + 1);
        let n = 40 + rng.below(400);
        let p = rng.uniform(0.005, 0.08);
        (seed, random_graph(n, p, &mut rng))
    })
}

#[test]
fn prop_partition_is_total_balanced_and_nonempty() {
    for (seed, csr) in random_cases(25) {
        let k = 2 + (seed as usize % 9);
        let p = partition(&csr, &PartitionConfig::new(k, seed));
        assert_eq!(p.assign.len(), csr.n);
        assert!(p.assign.iter().all(|&a| (a as usize) < k), "seed {seed}");
        let q = quality(&csr, &p.assign, k);
        assert!(q.min_part > 0, "seed {seed}: empty part {q:?}");
        assert!(q.balance <= 2.5, "seed {seed}: balance {q:?}");
        // cut is consistent with a direct recount
        assert_eq!(q.edge_cut, edge_cut(&csr, &p.assign));
    }
}

#[test]
fn prop_partition_never_worse_than_random_on_average() {
    let mut better = 0;
    let mut total = 0;
    for (seed, csr) in random_cases(12) {
        if csr.num_undirected_edges() < 20 {
            continue;
        }
        let k = 4;
        let p = partition(&csr, &PartitionConfig::new(k, seed));
        let mut rng = Rng::new(seed + 1000);
        let rand_assign: Vec<u32> = (0..csr.n).map(|_| rng.below(k) as u32).collect();
        if edge_cut(&csr, &p.assign) <= edge_cut(&csr, &rand_assign) {
            better += 1;
        }
        total += 1;
    }
    assert!(better * 10 >= total * 9, "partitioner lost to random: {better}/{total}");
}

#[test]
fn prop_shard_views_partition_nodes_exactly_once() {
    for (seed, csr) in random_cases(15) {
        let k = 2 + (seed as usize % 6);
        let p = partition(&csr, &PartitionConfig::new(k, seed));
        let views = shard_views(&csr, &p.assign, k);
        let mut owner_count = vec![0usize; csr.n];
        for v in &views {
            assert!(v.nodes.windows(2).all(|w| w[0] < w[1]), "seed {seed}: cores unsorted");
            assert!(v.halo.windows(2).all(|w| w[0] < w[1]), "seed {seed}: halo unsorted");
            for &u in &v.nodes {
                owner_count[u as usize] += 1;
            }
            for &h in &v.halo {
                // halo nodes are owned elsewhere and touch this shard's core
                assert!(v.nodes.binary_search(&h).is_err(), "seed {seed}: halo node is core");
                assert!(p.assign[h as usize] != v.shard_id as u32, "seed {seed}");
                assert!(
                    csr.neighbors(h as usize)
                        .iter()
                        .any(|&x| p.assign[x as usize] == v.shard_id as u32),
                    "seed {seed}: halo node {h} has no core neighbor"
                );
            }
        }
        // every node is core in exactly one shard
        assert!(
            owner_count.iter().all(|&c| c == 1),
            "seed {seed}: node owned by != 1 shard: {owner_count:?}"
        );
        // contiguous_perm is a valid permutation of the node ids
        let mut perm = p.contiguous_perm();
        perm.sort_unstable();
        assert_eq!(perm, (0..csr.n as u32).collect::<Vec<_>>(), "seed {seed}");
    }
}

#[test]
fn prop_shard_local_csr_roundtrips_parent_edges() {
    use std::collections::BTreeSet;
    for (seed, csr) in random_cases(15) {
        let k = 2 + (seed as usize % 5);
        let p = partition(&csr, &PartitionConfig::new(k, seed ^ 0x51));
        let views = shard_views(&csr, &p.assign, k);
        let mut rebuilt: BTreeSet<(u32, u32)> = BTreeSet::new();
        for v in &views {
            for lu in 0..v.csr.n {
                let gu = v.global_of(lu as u32);
                for &lv in v.csr.neighbors(lu) {
                    let gv = v.global_of(lv);
                    // every shard-local edge maps to a real parent edge...
                    assert!(
                        csr.has_edge(gu as usize, gv as usize),
                        "seed {seed}: phantom edge {gu}-{gv}"
                    );
                    // ...and touches at least one core endpoint (halo-halo
                    // edges belong to some other shard)
                    assert!(
                        lu < v.n_core() || (lv as usize) < v.n_core(),
                        "seed {seed}: halo-halo edge {gu}-{gv}"
                    );
                    rebuilt.insert((gu.min(gv), gu.max(gv)));
                }
            }
        }
        // union over shards reproduces the parent edge set exactly
        let parent: BTreeSet<(u32, u32)> = (0..csr.n as u32)
            .flat_map(|u| {
                csr.neighbors(u as usize).iter().map(move |&vv| (u.min(vv), u.max(vv)))
            })
            .collect();
        assert_eq!(rebuilt, parent, "seed {seed}: edge round-trip mismatch");
    }
}

fn attr_graph(csr: Csr, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let n = csr.n;
    let d_x = 8;
    let features: Vec<f32> = (0..n * d_x).map(|_| rng.normal() as f32).collect();
    let labels: Vec<u16> = (0..n).map(|_| rng.below(4) as u16).collect();
    let split: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
    Graph::new(csr, d_x, 4, features, labels, split)
}

/// Old-layout dense reference blocks built straight from the graph, padded
/// to (bb, bh) — exactly what the pre-refactor sampler materialized.
fn dense_reference(
    g: &Graph,
    batch: &[u32],
    halo: &[u32],
    bb: usize,
    bh: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let n = g.n();
    let mut mark = vec![0u8; n];
    let mut pos = vec![u32::MAX; n];
    for (i, &u) in batch.iter().enumerate() {
        mark[u as usize] = 1;
        pos[u as usize] = i as u32;
    }
    for (i, &u) in halo.iter().enumerate() {
        mark[u as usize] = 2;
        pos[u as usize] = i as u32;
    }
    let mut abb = vec![0f32; bb * bb];
    let mut abh = vec![0f32; bb * bh];
    let mut ahh = vec![0f32; bh * bh];
    for (i, &u) in batch.iter().enumerate() {
        let u = u as usize;
        abb[i * bb + i] = g.self_w[u];
        for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
            let v = g.csr.neighbors[ei] as usize;
            match mark[v] {
                1 => abb[i * bb + pos[v] as usize] = g.edge_w[ei],
                2 => abh[i * bh + pos[v] as usize] = g.edge_w[ei],
                _ => {}
            }
        }
    }
    for (i, &u) in halo.iter().enumerate() {
        let u = u as usize;
        ahh[i * bh + i] = g.self_w[u];
        for ei in g.csr.offsets[u] as usize..g.csr.offsets[u + 1] as usize {
            let v = g.csr.neighbors[ei] as usize;
            if mark[v] == 2 {
                ahh[i * bh + pos[v] as usize] = g.edge_w[ei];
            }
        }
    }
    (abb, abh, ahh)
}

#[test]
fn prop_sparse_blocks_roundtrip_to_old_dense_layout() {
    for (seed, csr) in random_cases(15) {
        let g = attr_graph(csr, seed);
        let mut rng = Rng::new(seed + 5);
        let nb = 1 + rng.below(g.n() / 2);
        let mut batch: Vec<u32> =
            rng.sample_indices(g.n(), nb).into_iter().map(|x| x as u32).collect();
        batch.sort_unstable();
        // padded bucket exercises the to_dense zero-padding path
        let buckets = Buckets(vec![(g.n(), g.n())]);
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &buckets, &HaloSampler::none(), &mut rng)
            .unwrap();
        assert_eq!(sb.dropped_halo, 0);
        let (abb, abh, ahh) = sb.to_dense();
        let (want_bb, want_bh, want_hh) =
            dense_reference(&g, &sb.batch, &sb.halo, sb.bucket_b, sb.bucket_h);
        assert_eq!(abb, want_bb, "seed {seed}: A_bb dense mismatch");
        assert_eq!(abh, want_bh, "seed {seed}: A_bh dense mismatch");
        assert_eq!(ahh, want_hh, "seed {seed}: A_hh dense mismatch");

        // sparse values are exact global-normalization gathers
        let (ew, sw) = gcn_normalize(&g.csr);
        for (i, &u) in sb.batch.iter().enumerate() {
            let u = u as usize;
            let (cols, vals) = sb.a_bb.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
            for (&j, &w) in cols.iter().zip(vals) {
                if j as usize == i {
                    assert_eq!(w, sw[u]);
                } else {
                    let v = sb.batch[j as usize];
                    let e = g.csr.neighbors(u).binary_search(&v).unwrap();
                    assert_eq!(w, ew[g.csr.offsets[u] as usize + e]);
                }
            }
        }

        // beta padding + range invariants under every score fn
        for score in [
            BetaScore::XSquared,
            BetaScore::TwoXMinusXSquared,
            BetaScore::X,
            BetaScore::One,
            BetaScore::SinX,
        ] {
            let beta = beta_vector(&sb, 0.7, score);
            assert!(beta.iter().all(|&b| (0.0..=1.0).contains(&b)));
            assert!(beta[sb.halo.len()..].iter().all(|&b| b == 0.0));
        }
    }
}

/// Full-batch mini-batch step (V_B = V, no halo) through the native
/// backend must reproduce the exact full-graph oracle gradients — the
/// paper's Theorem 1 consistency check, per architecture.
#[test]
fn prop_native_full_batch_step_matches_exact_oracle() {
    let exec = NativeExecutor::new();
    for (case, arch_name) in [(0u64, "gcn"), (1u64, "gcnii")] {
        for seed in 0..4u64 {
            let mut rng = Rng::new(seed * 31 + case * 7 + 2);
            let n = 30 + rng.below(120);
            let csr = random_graph(n, 0.06, &mut rng);
            let g = attr_graph(csr, seed + 100);
            let arch = match arch_name {
                "gcn" => ArchInfo::gcn(3, g.d_x, 16, g.n_class),
                _ => ArchInfo::gcnii(3, g.d_x, 16, g.n_class),
            };
            let model = ModelSpec {
                profile: "custom".into(),
                arch_name: arch_name.into(),
                arch,
            };
            let mut prng = Rng::new(seed ^ 0x51DE);
            let params = Params::init(&model.arch, &mut prng);
            let n_train = g.split.iter().filter(|&&s| s == 0).count().max(1);

            let batch: Vec<u32> = (0..g.n() as u32).collect();
            let sb = build_subgraph(
                &g,
                &batch,
                AdjacencyPolicy::GlobalWithHalo,
                &Buckets::unbounded(),
                &HaloSampler::none(),
                &mut rng,
            )
            .unwrap();
            assert!(sb.halo.is_empty(), "full batch has no halo");
            let l = model.arch.l;
            let inputs = StepInputs {
                graph: &g,
                sb: &sb,
                model: &model,
                params: &params,
                hist_h: (1..l).map(|_| Vec::new()).collect(),
                hist_v: (1..l).map(|_| Vec::new()).collect(),
                beta: Vec::new(),
                bwd_scale: 1.0,
                vscale: 1.0 / n_train as f32,
                grad_scale: 1.0,
                top: None,
                ws: None,
            };
            let step = exec.forward_backward(&inputs).unwrap();
            let oracle = exec.full_grad(&g, &params, &model).unwrap();
            let rel = grad_rel_err(&step.grads, &oracle.grads);
            assert!(
                rel < 1e-4,
                "{arch_name} seed {seed}: native step vs oracle rel err {rel}"
            );
            // losses agree too (step loss_sum is the unnormalized train CE)
            let step_loss = step.loss_sum / n_train as f64;
            assert!(
                (step_loss - oracle.train_loss).abs() < 1e-5 * (1.0 + oracle.train_loss.abs()),
                "{arch_name} seed {seed}: loss {step_loss} vs {}",
                oracle.train_loss
            );
        }
    }
}

#[test]
fn prop_batcher_every_epoch_is_a_partition_of_nodes() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let n = 50 + rng.below(300);
        let k = 2 + rng.below(12);
        let mut clusters = vec![Vec::new(); k];
        for u in 0..n as u32 {
            clusters[rng.below(k)].push(u);
        }
        clusters.retain(|c| !c.is_empty());
        let c_per = 1 + rng.below(clusters.len());
        for mode in [BatcherMode::Stochastic, BatcherMode::Fixed] {
            let mut b = Batcher::new(clusters.clone(), c_per, mode, seed);
            for _ in 0..3 {
                let mut seen: Vec<u32> = b.epoch_batches().iter().flat_map(|grp| grp.iter().copied()).collect();
                seen.sort_unstable();
                seen.dedup();
                let expect: usize = clusters.iter().map(|c| c.len()).sum();
                assert_eq!(seen.len(), expect, "seed {seed} mode {mode:?}");
            }
        }
    }
}

#[test]
fn prop_history_scatter_gather_roundtrip() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let n = 20 + rng.below(200);
        let dims = vec![1 + rng.below(16), 1 + rng.below(16)];
        let mut h = History::new(n, &dims);
        for l in 1..=2usize {
            let k = 1 + rng.below(n);
            let idx: Vec<u32> = {
                let mut v: Vec<u32> =
                    rng.sample_indices(n, k).into_iter().map(|x| x as u32).collect();
                v.sort_unstable();
                v
            };
            let d = dims[l - 1];
            let src: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
            h.scatter_h(l, &idx, &src);
            h.scatter_v(l, &idx, &src);
            let rows = k + rng.below(8);
            let back = h.gather_h(l, &idx, rows);
            assert_eq!(&back[..k * d], &src[..]);
            assert!(back[k * d..].iter().all(|&x| x == 0.0));
            let backv = h.gather_v(l, &idx, rows);
            assert_eq!(&backv[..k * d], &src[..]);
        }
    }
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{ctx}: elem {i}: {g} vs {w}"
        );
    }
}

/// Blocked GEMM kernels vs the retained naive references, across odd
/// shapes: dims that are not multiples of the tile sizes, singleton dims,
/// and shapes big enough to cross the parallel threshold.
#[test]
fn prop_blocked_gemm_matches_reference() {
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (17, 33, 9),
        (16, 64, 16),
        (100, 1, 7),
        (5, 129, 1),
        (33, 65, 130),
        (257, 19, 31),
        (70, 70, 70),
    ];
    let mut rng = Rng::new(0xB10C);
    for &(m, k, n) in shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let ctx = format!("matmul {m}x{k}x{n}");
        assert_close(
            &gemm::matmul(&a, m, k, &b, n),
            &gemm::reference::matmul(&a, m, k, &b, n),
            1e-5,
            &ctx,
        );
        // fused bias
        let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut fused = vec![0f32; m * n];
        gemm::matmul_bias_into(&mut fused, &a, m, k, &b, n, &bias);
        let mut want = gemm::reference::matmul(&a, m, k, &b, n);
        gemm::reference::add_bias_rows(&mut want, &bias);
        assert_close(&fused, &want, 1e-5, &format!("{ctx} +bias"));
        // nt: a[m, k] @ bt[n, k]^T
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        assert_close(
            &gemm::matmul_nt(&a, m, k, &bt, n),
            &gemm::reference::matmul_nt(&a, m, k, &bt, n),
            1e-5,
            &format!("matmul_nt {m}x{k}x{n}"),
        );
        // tn: a[m, k]^T @ c[m, n]
        let c: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        assert_close(
            &gemm::matmul_tn(&a, m, k, &c, n),
            &gemm::reference::matmul_tn(&a, m, k, &c, n),
            1e-5,
            &format!("matmul_tn {m}x{k}x{n}"),
        );
    }
}

/// The runtime-dispatched SIMD primitives vs the scalar oracle, across odd
/// lengths (non-multiples of the 8-wide vector, singletons, empties) and
/// unaligned slice starts (offsets 0..3 from the allocation). Elementwise
/// ops are pinned at ≤ 1e-5; `dot` reassociates across accumulators so it
/// gets a wider band here, while the GEMM-level tests below pin the N/T
/// kernel it feeds at ≤ 1e-5 on realistic shapes.
#[test]
fn prop_simd_primitives_match_scalar() {
    let scalar = simd::ops(SimdLevel::Scalar);
    let active = simd::ops_auto();
    let mut rng = Rng::new(0x51D0);
    let lens = [0usize, 1, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100, 257];
    for &len in &lens {
        for off in 0..3usize {
            let total = len + off;
            let src: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
            let src2: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
            let base: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();
            let a = rng.normal() as f32;
            let ctx = |p: &str| format!("{p} len {len} off {off}");

            let mut want = base.clone();
            (scalar.axpy)(&mut want[off..], &src[off..], a);
            let mut got = base.clone();
            (active.axpy)(&mut got[off..], &src[off..], a);
            assert_close(&got, &want, 1e-5, &ctx("axpy"));

            let mut want = base.clone();
            (scalar.scale)(&mut want[off..], &src[off..], a);
            let mut got = base.clone();
            (active.scale)(&mut got[off..], &src[off..], a);
            assert_close(&got, &want, 1e-5, &ctx("scale"));

            let wd = (scalar.dot)(&src[off..], &src2[off..]);
            let gd = (active.dot)(&src[off..], &src2[off..]);
            assert!(
                (gd - wd).abs() <= 1e-4 * (1.0 + wd.abs()),
                "{}: {gd} vs {wd}",
                ctx("dot")
            );

            let mut want = base.clone();
            (scalar.relu_copy)(&mut want[off..], &src[off..]);
            let mut got = base.clone();
            (active.relu_copy)(&mut got[off..], &src[off..]);
            assert_eq!(got, want, "{}", ctx("relu_copy"));

            let gam = 0.3f32;
            let mut wz = base.clone();
            let mut wa = vec![0f32; total];
            (scalar.mix_relu)(&mut wz[off..], &mut wa[off..], &src[off..], gam);
            let mut gz = base.clone();
            let mut ga = vec![0f32; total];
            (active.mix_relu)(&mut gz[off..], &mut ga[off..], &src[off..], gam);
            assert_close(&gz, &wz, 1e-5, &ctx("mix_relu z"));
            assert_close(&ga, &wa, 1e-5, &ctx("mix_relu act"));

            let bcoef = 0.4f32;
            let mut want = base.clone();
            (scalar.combine)(&mut want[off..], &src[off..], &src2[off..], bcoef);
            let mut got = base.clone();
            (active.combine)(&mut got[off..], &src[off..], &src2[off..], bcoef);
            assert_close(&got, &want, 1e-5, &ctx("combine"));
        }
    }
}

/// SIMD-dispatched blocked GEMM vs the scalar blocked kernels across odd
/// shapes: widths that are not multiples of the 8-lane vector, d = 1, and
/// shapes crossing the parallel threshold.
#[test]
fn prop_simd_gemm_matches_scalar_blocked() {
    let fast = Kernels::blocked();
    let slow = Kernels::blocked_scalar();
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (9, 8, 8),
        (17, 33, 9),
        (16, 64, 16),
        (100, 1, 7),
        (5, 129, 1),
        (33, 65, 130),
        (257, 19, 31),
        (70, 70, 70),
    ];
    let mut rng = Rng::new(0x51D1);
    for &(m, k, n) in shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ctx = format!("{m}x{k}x{n}");

        let mut want = vec![0f32; m * n];
        slow.matmul_into(&mut want, &a, m, k, &b, n);
        let mut got = vec![0f32; m * n];
        fast.matmul_into(&mut got, &a, m, k, &b, n);
        assert_close(&got, &want, 1e-5, &format!("simd matmul {ctx}"));

        let mut want = vec![0f32; m * n];
        slow.matmul_bias_into(&mut want, &a, m, k, &b, n, &bias);
        let mut got = vec![0f32; m * n];
        fast.matmul_bias_into(&mut got, &a, m, k, &b, n, &bias);
        assert_close(&got, &want, 1e-5, &format!("simd matmul+bias {ctx}"));

        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; m * n];
        slow.matmul_nt_into(&mut want, &a, m, k, &bt, n);
        let mut got = vec![0f32; m * n];
        fast.matmul_nt_into(&mut got, &a, m, k, &bt, n);
        assert_close(&got, &want, 1e-5, &format!("simd matmul_nt {ctx}"));

        let c: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0f32; k * n];
        slow.matmul_tn_into(&mut want, &a, m, k, &c, n);
        let mut got = vec![0f32; k * n];
        fast.matmul_tn_into(&mut got, &a, m, k, &c, n);
        assert_close(&got, &want, 1e-5, &format!("simd matmul_tn {ctx}"));
    }
}

/// SIMD-dispatched SpMM vs the scalar ops over random sparse blocks with
/// empty rows, including scaled accumulation into a pre-filled buffer.
#[test]
fn prop_simd_spmm_matches_scalar() {
    let scalar = simd::ops(SimdLevel::Scalar);
    let mut rng = Rng::new(0x51D2);
    for case in 0..6u64 {
        let n_rows = 1 + rng.below(150);
        let n_cols = 1 + rng.below(120);
        let p = rng.uniform(0.0, 0.1); // sparse enough that empty rows occur
        let mut dense = vec![0f32; n_rows * n_cols];
        for v in dense.iter_mut() {
            if rng.next_f64() < p {
                *v = rng.normal() as f32;
            }
        }
        let blk = CsrBlock::from_dense(n_rows, n_cols, &dense);
        for &d in &[1usize, 7, 8, 64, 129] {
            let x: Vec<f32> = (0..n_cols * d).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0.5f32; n_rows * d];
            blk.par_spmm_acc_tiled_with(scalar, &x, d, 0.7, &mut want);
            let mut got = vec![0.5f32; n_rows * d];
            blk.par_spmm_acc_tiled(&x, d, 0.7, &mut got);
            assert_close(&got, &want, 1e-5, &format!("simd spmm case {case} d {d}"));
        }
    }
}

/// The fused epilogue entry points vs the corresponding unfused sequences,
/// for every kernel family: fused(GEMM + bias + ReLU) and the GCNII
/// fused(GEMM + residual mix + ReLU) must be value-comparable within 1e-6.
#[test]
fn prop_fused_epilogues_match_unfused() {
    let mut rng = Rng::new(0x51D3);
    for kern in [Kernels::blocked(), Kernels::blocked_scalar(), Kernels::reference()] {
        // bias + ReLU, rectangular shapes
        let rect = [(1usize, 1usize, 1usize), (3, 5, 2), (17, 33, 9), (64, 32, 48), (257, 19, 31)];
        for &(m, k, n) in &rect {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut want_z = vec![0f32; m * n];
            kern.matmul_bias_into(&mut want_z, &a, m, k, &b, n, &bias);
            let want_act: Vec<f32> =
                want_z.iter().map(|&z| if z > 0.0 { z } else { 0.0 }).collect();
            let mut z = vec![0f32; m * n];
            let mut act = vec![0f32; m * n];
            kern.matmul_bias_relu_into(&mut z, &mut act, &a, m, k, &b, n, &bias);
            let ctx = format!("{kern:?} fused bias+relu {m}x{k}x{n}");
            assert_close(&z, &want_z, 1e-6, &ctx);
            assert_close(&act, &want_act, 1e-6, &ctx);
        }
        // residual mix + ReLU, square layers (the GCNII shape)
        // (200, 32) crosses the parallel threshold for the fused-mix path
        for &(m, d) in &[(1usize, 1usize), (3, 4), (17, 16), (33, 40), (129, 24), (200, 32)] {
            let s: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
            let gam = 0.35f32;
            let mut sw = vec![0f32; m * d];
            kern.matmul_into(&mut sw, &s, m, d, &w, d);
            let want_z: Vec<f32> = s
                .iter()
                .zip(&sw)
                .map(|(&sv, &swv)| (1.0 - gam) * sv + gam * swv)
                .collect();
            let want_act: Vec<f32> =
                want_z.iter().map(|&z| if z > 0.0 { z } else { 0.0 }).collect();
            let mut z = vec![0f32; m * d];
            let mut act = vec![0f32; m * d];
            kern.matmul_mix_relu_into(&mut z, &mut act, &s, m, d, &w, d, gam);
            let ctx = format!("{kern:?} fused mix+relu {m}x{d}");
            assert_close(&z, &want_z, 1e-6, &ctx);
            assert_close(&act, &want_act, 1e-6, &ctx);
        }
    }
}

/// Tiled SpMM vs the serial reference over random sparse blocks with
/// empty rows, d = 1, and d straddling the tile width.
#[test]
fn prop_tiled_spmm_matches_reference() {
    let mut rng = Rng::new(0x59A7);
    for case in 0..8u64 {
        let n_rows = 1 + rng.below(200);
        let n_cols = 1 + rng.below(150);
        let p = rng.uniform(0.0, 0.1); // sparse enough that empty rows occur
        let mut dense = vec![0f32; n_rows * n_cols];
        for v in dense.iter_mut() {
            if rng.next_f64() < p {
                *v = rng.normal() as f32;
            }
        }
        let blk = CsrBlock::from_dense(n_rows, n_cols, &dense);
        for &d in &[1usize, 7, 64, 129, 256] {
            let x: Vec<f32> = (0..n_cols * d).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0f32; n_rows * d];
            blk.spmm_acc(&x, d, &mut want);
            let got = blk.par_spmm_tiled(&x, d);
            assert_close(&got, &want, 1e-6, &format!("case {case} d {d}"));
        }
    }
}

/// The optimized step configuration (blocked kernels + workspace reuse)
/// must agree with the pre-optimization configuration (reference kernels,
/// allocate-per-step) on a real compensated subgraph step — gradients,
/// loss, and every history write-back, for both architectures. Running the
/// workspace path twice also proves buffer recycling cannot leak state
/// between steps.
#[test]
fn prop_optimized_step_matches_reference_step() {
    let fast = NativeExecutor::new();
    let slow = NativeExecutor::with_reference_kernels();
    for (case, arch_name) in [(0u64, "gcn"), (1u64, "gcnii")] {
        let mut rng = Rng::new(case * 131 + 17);
        let n = 120 + rng.below(150);
        let csr = random_graph(n, 0.05, &mut rng);
        let g = attr_graph(csr, case + 7);
        let arch = match arch_name {
            "gcn" => ArchInfo::gcn(3, g.d_x, 16, g.n_class),
            _ => ArchInfo::gcnii(3, g.d_x, 16, g.n_class),
        };
        let model = ModelSpec { profile: "custom".into(), arch_name: arch_name.into(), arch };
        let mut prng = Rng::new(case ^ 0xF457);
        let params = Params::init(&model.arch, &mut prng);
        let batch: Vec<u32> = (0..(g.n() / 2) as u32).collect();
        let sb = build_subgraph(&g, &batch, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut rng)
            .unwrap();
        assert!(!sb.halo.is_empty(), "test needs a halo");
        let nh = sb.halo.len();
        let l = model.arch.l;
        let dims = model.arch.dims.clone();
        let hist_h: Vec<Vec<f32>> = (1..l)
            .map(|li| (0..nh * dims[li]).map(|_| prng.normal() as f32).collect())
            .collect();
        let hist_v: Vec<Vec<f32>> = (1..l)
            .map(|li| (0..nh * dims[li]).map(|_| prng.normal() as f32).collect())
            .collect();
        let beta = beta_vector(&sb, 0.8, BetaScore::TwoXMinusXSquared);
        let ws = std::sync::Mutex::new(StepWorkspace::new());
        let mk_inputs = |use_ws: bool| StepInputs {
            graph: &g,
            sb: &sb,
            model: &model,
            params: &params,
            hist_h: hist_h.clone(),
            hist_v: hist_v.clone(),
            beta: beta.clone(),
            bwd_scale: 1.0,
            vscale: 0.01,
            grad_scale: 1.5,
            top: None,
            ws: if use_ws { Some(&ws) } else { None },
        };
        let baseline = slow.forward_backward(&mk_inputs(false)).unwrap();
        let mut miss_trace: Vec<u64> = Vec::new();
        for round in 0..2 {
            let inputs = mk_inputs(true);
            let opt = fast.forward_backward(&inputs).unwrap();
            // recycle escaped buffers like the trainer does, then re-run
            {
                let mut w = ws.lock().unwrap();
                let StepInputs { hist_h, hist_v, beta, .. } = inputs;
                w.put(beta);
                w.put_all(hist_h);
                w.put_all(hist_v);
                let mut opt_outs = opt;
                assert!(
                    (opt_outs.loss_sum - baseline.loss_sum).abs()
                        <= 1e-5 * (1.0 + baseline.loss_sum.abs()),
                    "{arch_name} round {round}: loss {} vs {}",
                    opt_outs.loss_sum,
                    baseline.loss_sum
                );
                // kernel variants may differ at float-rounding level; a
                // flipped argmax on a near-tie would move `correct` by 1
                assert!((opt_outs.correct - baseline.correct).abs() <= 1.0);
                let rel = grad_rel_err(&opt_outs.grads, &baseline.grads);
                assert!(rel < 1e-5, "{arch_name} round {round}: grads rel err {rel}");
                for (a, b) in opt_outs.new_h.iter().zip(&baseline.new_h) {
                    assert_close(a, b, 1e-5, &format!("{arch_name} new_h"));
                }
                for (a, b) in opt_outs.new_v.iter().zip(&baseline.new_v) {
                    assert_close(a, b, 1e-5, &format!("{arch_name} new_v"));
                }
                for (a, b) in opt_outs.htilde.iter().zip(&baseline.htilde) {
                    assert_close(a, b, 1e-5, &format!("{arch_name} htilde"));
                }
                w.put_all(opt_outs.new_h.drain(..));
                w.put_all(opt_outs.new_v.drain(..));
                w.put_all(opt_outs.htilde.drain(..));
                miss_trace.push(w.misses());
            }
        }
        // identical second step: every grab must hit the warm pool
        assert_eq!(
            miss_trace[0], miss_trace[1],
            "{arch_name}: repeated step allocated fresh buffers"
        );
    }
}

/// Fixed-mode groups are identical across epochs and subgraph construction
/// is deterministic with unbounded buckets, so rebuilding any group yields
/// bit-identical blocks — the property that makes SubgraphCache sound.
#[test]
fn prop_fixed_groups_rebuild_identically() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed + 41);
        let n = 100 + rng.below(200);
        let csr = random_graph(n, 0.05, &mut rng);
        let g = attr_graph(csr, seed);
        let k = 4;
        let mut clusters = vec![Vec::new(); k];
        for u in 0..g.n() as u32 {
            clusters[rng.below(k)].push(u);
        }
        clusters.retain(|c| !c.is_empty());
        let mut batcher = Batcher::new(clusters, 2, BatcherMode::Fixed, seed);
        let e1 = batcher.epoch_batches();
        let e2 = batcher.epoch_batches();
        assert_eq!(e1, e2, "Fixed groups changed across epochs");
        for (i, b) in e1.iter().enumerate() {
            let mut r1 = Rng::new(seed * 3 + 1);
            let mut r2 = Rng::new(seed * 5 + 2); // different stream on purpose
            let sb1 =
                build_subgraph(&g, b, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut r1)
                    .unwrap();
            let sb2 =
                build_subgraph(&g, b, AdjacencyPolicy::GlobalWithHalo, &Buckets::unbounded(), &HaloSampler::none(), &mut r2)
                    .unwrap();
            assert_eq!(sb1.batch, sb2.batch, "group {i}");
            assert_eq!(sb1.halo, sb2.halo, "group {i}");
            assert_eq!(sb1.a_bb, sb2.a_bb, "group {i}");
            assert_eq!(sb1.a_bh, sb2.a_bh, "group {i}");
            assert_eq!(sb1.a_hh, sb2.a_hh, "group {i}");
            assert_eq!(sb1.a_hb, sb2.a_hb, "group {i}");
        }
    }
}

/// Serve-path micro-batch tiling invariants: tiles partition the
/// deduplicated request set — every requested node lands in exactly one
/// tile, the union covers the request set, no tile exceeds the knob, and
/// tiles stay sorted (the sampler requires sorted batches).
#[test]
fn prop_serve_tiling_covers_each_requested_node_once() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed * 91 + 3);
        let n = 30 + rng.below(500);
        let k = 1 + rng.below(2 * n);
        // requests arrive with duplicates and in arbitrary order
        let requested: Vec<u32> = (0..k).map(|_| rng.below(n) as u32).collect();
        let max_tile = 1 + rng.below(64);
        let mut unique = requested.clone();
        unique.sort_unstable();
        unique.dedup();
        let tiles = plan_tiles(&unique, max_tile);
        let mut count = vec![0usize; n];
        for t in &tiles {
            assert!(!t.is_empty(), "seed {seed}: empty tile");
            assert!(t.len() <= max_tile, "seed {seed}: tile over the knob");
            assert!(t.windows(2).all(|w| w[0] < w[1]), "seed {seed}: tile unsorted");
            for &u in t {
                count[u as usize] += 1;
            }
        }
        for &u in &unique {
            assert_eq!(count[u as usize], 1, "seed {seed}: node {u} not served exactly once");
        }
        let covered: usize = count.iter().sum();
        assert_eq!(covered, unique.len(), "seed {seed}: tile union != request set");
    }
}

/// Serving the same request set in any order (and with duplicates) gives
/// identical per-node outputs: tiling is a function of the deduplicated
/// sorted set only.
#[test]
fn prop_serve_request_order_is_irrelevant() {
    for (case, arch_name) in [(0u64, "gcn"), (1u64, "gcnii")] {
        let mut rng = Rng::new(case * 47 + 11);
        let n = 120 + rng.below(120);
        let csr = random_graph(n, 0.05, &mut rng);
        let g = attr_graph(csr, case + 31);
        let arch = match arch_name {
            "gcn" => ArchInfo::gcn(2, g.d_x, 12, g.n_class),
            _ => ArchInfo::gcnii(2, g.d_x, 12, g.n_class),
        };
        let model = ModelSpec { profile: "custom".into(), arch_name: arch_name.into(), arch };
        let params = Params::init(&model.arch, &mut Rng::new(case ^ 0x5E12));
        // a tiny tile knob forces multi-tile assembly
        let opts = ServeOptions { mode: ServeMode::Exact, tile_nodes: 17, ..Default::default() };
        let eng = ServeEngine::new(std::sync::Arc::new(g), model, params, opts).unwrap();
        let mut nodes: Vec<u32> = (0..n as u32).step_by(2).collect();
        nodes.push(0); // duplicate
        let forward = eng.predict(&nodes).unwrap();
        let mut shuffled = nodes.clone();
        Rng::new(case + 99).shuffle(&mut shuffled);
        let back = eng.predict(&shuffled).unwrap();
        let by_node = |preds: &[lmc::serve::Prediction]| {
            let mut m = std::collections::HashMap::new();
            for p in preds {
                let prev = m.insert(p.node, p.logits.clone());
                if let Some(prev) = prev {
                    assert_eq!(prev, p.logits, "{arch_name}: duplicate served differently");
                }
            }
            m
        };
        assert_eq!(by_node(&forward), by_node(&back), "{arch_name}: order changed outputs");
    }
}

/// Params save/load is bitwise: every f32 bit pattern (signed zero,
/// subnormals, NaN payloads) survives the disk round-trip for random
/// architectures of both families.
#[test]
fn prop_params_save_load_roundtrip_is_bitwise() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed * 17 + 5);
        let l = 2 + rng.below(3);
        let d_x = 1 + rng.below(40);
        let hidden = 1 + rng.below(48);
        let c = 2 + rng.below(12);
        let arch = if seed % 2 == 0 {
            ArchInfo::gcn(l, d_x, hidden, c)
        } else {
            ArchInfo::gcnii(l, d_x, hidden, c)
        };
        let mut p = Params::init(&arch, &mut Rng::new(seed ^ 0xD15C));
        // plant bit patterns a lossy round-trip would destroy
        let d0 = &mut p.tensors[0].data;
        d0[0] = -0.0;
        if d0.len() > 3 {
            d0[1] = f32::from_bits(0x7fc0_0abc); // NaN payload
            d0[2] = f32::from_bits(0x0000_0001); // smallest subnormal
            d0[3] = f32::NEG_INFINITY;
        }
        let path = std::env::temp_dir().join(format!(
            "lmc_params_prop_{}_{}.bin",
            std::process::id(),
            seed
        ));
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(p.names, q.names, "seed {seed}");
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a.shape, b.shape, "seed {seed}");
            let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "seed {seed}: bit patterns drifted");
        }
    }
}

/// The quantization error bar the bf16 history store documents: encode →
/// decode of any normal f32 is within 2^-8 relative of the input (bf16
/// keeps 8 significand bits, so round-to-nearest-even lands within half a
/// ulp = 2^-9 ≤ 2^-8), and the f16 store within 2^-10 over its normal
/// range. Zeros, infinities, and NaN-ness survive both.
#[test]
fn prop_half_roundtrip_error_is_bounded() {
    let mut rng = Rng::new(0xBF16);
    for case in 0..4000u32 {
        // magnitudes across the shared normal range of both formats
        let exp = rng.uniform(-14.0, 15.0);
        let x = (rng.normal() as f32) * (2f32).powf(exp as f32);
        // stay inside f16's finite range: past 65504 it rounds to inf and
        // the relative-error claim no longer applies (bf16 reaches f32 max)
        if x == 0.0 || !x.is_finite() || x.abs() > 32768.0 {
            continue;
        }
        let xb = bf16_to_f32(bf16_from_f32(x));
        assert!(
            (xb - x).abs() <= x.abs() * (1.0 / 256.0),
            "case {case}: bf16 {x} -> {xb} off by more than 2^-8 relative"
        );
        let xh = f16_to_f32(f16_from_f32(x));
        assert!(
            (xh - x).abs() <= x.abs() * (1.0 / 1024.0) + f32::EPSILON,
            "case {case}: f16 {x} -> {xh} off by more than 2^-10 relative"
        );
    }
    // specials survive exactly
    for v in [0.0f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, 1.0, -2.0, 0.5] {
        assert_eq!(bf16_to_f32(bf16_from_f32(v)).to_bits(), v.to_bits(), "bf16 {v}");
        assert_eq!(f16_to_f32(f16_from_f32(v)), v, "f16 {v}");
    }
    assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
    assert!(f16_to_f32(f16_from_f32(f32::NAN)).is_nan());
}

/// The SIMD decode path (the dequant-fused gather behind
/// `History::gather_h_into` on a bf16 store) agrees bitwise with the
/// scalar encode/decode oracle on random rows — the bf16 half of the
/// satellite "scalar oracle vs SIMD decode" pin.
#[test]
fn prop_bf16_store_gather_matches_scalar_decode_bitwise() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed * 131 + 7);
        let n = 30 + rng.below(200);
        let dims = vec![1 + rng.below(40)];
        let d = dims[0];
        let mut h = History::with_dtype(n, &dims, HistDtype::Bf16);
        let k = 1 + rng.below(n);
        let idx: Vec<u32> = {
            let mut v: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|x| x as u32).collect();
            v.sort_unstable();
            v
        };
        let src: Vec<f32> =
            (0..k * d).map(|_| (rng.normal() as f32) * 8.0).collect();
        h.scatter_h(1, &idx, &src);
        let mut got = vec![0f32; k * d];
        h.gather_h_into(1, &idx, &mut got);
        for (i, (&g, &s)) in got.iter().zip(&src).enumerate() {
            let want = bf16_to_f32(bf16_from_f32(s));
            assert_eq!(
                g.to_bits(),
                want.to_bits(),
                "seed {seed} elem {i}: SIMD decode {g} != scalar oracle {want}"
            );
        }
    }
}

/// One short LMC training run on cora-sim with `history_dtype = bf16`
/// tracks the f32 run: the quantization error (≤ 2^-8 relative per cached
/// element) is absorbed the same way bounded staleness is, so the epoch
/// losses stay within a 5% relative band — the documented tolerance the
/// README "Memory & precision" section pins. (The runs are not bitwise
/// comparable: halo compensation reads decoded rows.)
#[test]
fn prop_bf16_history_training_tracks_f32_loss() {
    let run = |dtype: HistDtype| {
        let cfg = RunConfig {
            dataset: DatasetId::CoraSim,
            arch: "gcn".into(),
            method: Method::Lmc,
            epochs: 2,
            eval_every: 2,
            seed: 1,
            history_dtype: dtype,
            ..Default::default()
        };
        let mut t =
            Trainer::new(std::sync::Arc::new(NativeExecutor::new()), cfg).unwrap();
        t.run().unwrap()
    };
    let full = run(HistDtype::F32);
    let quant = run(HistDtype::Bf16);
    assert_eq!(full.records.len(), quant.records.len());
    for (f, q) in full.records.iter().zip(&quant.records) {
        let (lf, lq) = (f.train_loss, q.train_loss);
        assert!(
            (lf - lq).abs() <= 0.05 * (1.0 + lf.abs()),
            "bf16 history diverged from f32: epoch loss {lq} vs {lf}"
        );
    }
    // and it still learns: same drop criterion the integration suite uses
    let first = quant.records.first().unwrap().train_loss;
    let last = quant.records.last().unwrap().train_loss;
    assert!(last < first, "bf16 run did not learn ({first} -> {last})");
}

/// `LMCCKPT1` state blocks round-trip bitwise across random architectures
/// and every history dtype: encode → decode → re-encode is byte-identical
/// (including the raw quantized history words, which never pass through
/// f32), and a fresh trainer restored from the decoded state continues
/// bit-identically to the original.
#[test]
fn prop_checkpoint_state_roundtrips_bitwise() {
    use lmc::checkpoint::{decode_state, encode_state, TrainerState};
    for case in 0u64..6 {
        let arch = if case % 2 == 0 { "gcn" } else { "gcnii" };
        let dtype = match case % 3 {
            0 => HistDtype::F32,
            1 => HistDtype::Bf16,
            _ => HistDtype::F16,
        };
        let cfg = RunConfig {
            dataset: DatasetId::CoraSim,
            arch: arch.into(),
            method: Method::Lmc,
            epochs: 4,
            eval_every: usize::MAX,
            seed: 10 + case,
            history_dtype: dtype,
            ..Default::default()
        };
        let mut a = Trainer::new(std::sync::Arc::new(NativeExecutor::new()), cfg.clone()).unwrap();
        for _ in 0..2 {
            a.train_epoch().unwrap();
        }

        let state = TrainerState::capture(&a);
        let fp = format!("case-{case}");
        let bytes = encode_state(&state, &fp);
        let decoded = decode_state(&bytes, &fp).unwrap();
        let bytes2 = encode_state(&decoded, &fp);
        assert_eq!(bytes, bytes2, "case {case} ({arch}): re-encode differs");

        let mut b = Trainer::new(std::sync::Arc::new(NativeExecutor::new()), cfg).unwrap();
        decoded.restore_into(&mut b).unwrap();
        a.train_epoch().unwrap();
        b.train_epoch().unwrap();
        for (ta, tb) in a.params.tensors.iter().zip(&b.params.tensors) {
            let ba: Vec<u32> = ta.data.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = tb.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ba, bb, "case {case} ({arch}): diverged after restore");
        }
    }
}

#[test]
fn prop_datasets_deterministic_across_loads() {
    for &id in DatasetId::all() {
        let a = load(id, 3);
        let b = load(id, 3);
        assert_eq!(a.csr, b.csr, "{}", id.name());
        assert_eq!(a.features, b.features);
        assert_eq!(a.split, b.split);
        let c = load(id, 4);
        assert_ne!(a.csr, c.csr, "{} should vary with seed", id.name());
    }
}

/// Horvitz–Thompson unbiasedness of the halo sampler zoo: for every
/// subsampling policy, the seed-averaged subsampled batch-row aggregation
/// `A_bh^(s) @ x` converges to the full-halo aggregation — while the legacy
/// unrescaled bucket cap at the same keep fraction provably does not (its
/// expectation shrinks by the keep fraction).
#[test]
fn prop_halo_samplers_unbiased_aggregation() {
    let n_avg = 400;
    for case in 0..2u64 {
        let mut rng = Rng::new(case * 131 + 9);
        let n = 120 + rng.below(120);
        let csr = random_graph(n, 0.04, &mut rng);
        let g = attr_graph(csr, case + 17);
        let half = g.n() / 2;
        let batch: Vec<u32> = (0..half as u32).collect();
        // deterministic positive per-node signal (no cancellation, so the
        // relative L1 error below is well-conditioned)
        let x = |v: u32| 0.5 + (v % 7) as f32 * 0.1;

        let full = build_subgraph(
            &g,
            &batch,
            AdjacencyPolicy::GlobalWithHalo,
            &Buckets::unbounded(),
            &HaloSampler::none(),
            &mut Rng::new(0),
        )
        .unwrap();
        assert!(full.halo.len() >= 10, "case {case}: need a real halo");
        let full_agg: Vec<f64> = (0..batch.len())
            .map(|i| {
                let (cols, vals) = full.a_bh.row(i);
                cols.iter()
                    .zip(vals)
                    .map(|(&j, &w)| w as f64 * x(full.halo[j as usize]) as f64)
                    .sum()
            })
            .collect();
        let full_l1: f64 = full_agg.iter().map(|v| v.abs()).sum();
        assert!(full_l1 > 0.0);

        let rel_err_of = |sampler: &HaloSampler, buckets: &Buckets| -> f64 {
            let mut acc = vec![0f64; batch.len()];
            for s in 0..n_avg {
                let mut r = Rng::new(case * 100_000 + s as u64 + 1);
                let sb = build_subgraph(
                    &g,
                    &batch,
                    AdjacencyPolicy::GlobalWithHalo,
                    buckets,
                    sampler,
                    &mut r,
                )
                .unwrap();
                // kept halo must always be a subset of the full halo, and
                // core rows are never touched by halo subsampling
                assert_eq!(sb.batch, batch);
                for &h in &sb.halo {
                    assert!(full.halo.binary_search(&h).is_ok());
                }
                for (i, a) in acc.iter_mut().enumerate() {
                    let (cols, vals) = sb.a_bh.row(i);
                    *a += cols
                        .iter()
                        .zip(vals)
                        .map(|(&j, &w)| w as f64 * x(sb.halo[j as usize]) as f64)
                        .sum::<f64>();
                }
            }
            acc.iter()
                .zip(&full_agg)
                .map(|(a, f)| (a / n_avg as f64 - f).abs())
                .sum::<f64>()
                / full_l1
        };

        for kind in
            [HaloSamplerKind::Uniform, HaloSamplerKind::Labor, HaloSamplerKind::Importance]
        {
            let err = rel_err_of(&HaloSampler::new(kind, 0.5), &Buckets::unbounded());
            assert!(err < 0.1, "case {case}: {} sampler biased: rel L1 err {err}", kind.name());
        }

        // The legacy path at the same keep fraction: an unrescaled bucket
        // cap whose expected aggregation shrinks by ~the keep fraction.
        let cap = full.halo.len() / 2;
        let legacy_err =
            rel_err_of(&HaloSampler::none(), &Buckets(vec![(g.n(), cap)]));
        assert!(
            legacy_err > 0.25,
            "case {case}: legacy cap unexpectedly unbiased (rel L1 err {legacy_err})"
        );
    }
}

/// Every halo sampler preserves the epoch schedule: the batcher's groups
/// cover each core node exactly once per epoch, and a subsampling policy
/// only ever shrinks halos — core membership of every built subgraph is
/// exactly its group.
#[test]
fn prop_sampled_epoch_serves_each_core_node_once() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(seed + 71);
        let n = 100 + rng.below(150);
        let csr = random_graph(n, 0.04, &mut rng);
        let g = attr_graph(csr, seed);
        let k = 5;
        let mut clusters = vec![Vec::new(); k];
        for u in 0..g.n() as u32 {
            clusters[rng.below(k)].push(u);
        }
        clusters.retain(|c| !c.is_empty());
        for kind in [
            HaloSamplerKind::None,
            HaloSamplerKind::Uniform,
            HaloSamplerKind::Labor,
            HaloSamplerKind::Importance,
        ] {
            let sampler = HaloSampler::new(kind, 0.5);
            for mode in [BatcherMode::Stochastic, BatcherMode::Fixed] {
                let mut b = Batcher::new(clusters.clone(), 2, mode, seed);
                let mut served: Vec<u32> = Vec::new();
                for (i, grp) in b.epoch_batches().iter().enumerate() {
                    let mut r = rng.fork(i as u64);
                    let sb = build_subgraph(
                        &g,
                        grp,
                        AdjacencyPolicy::GlobalWithHalo,
                        &Buckets::unbounded(),
                        &sampler,
                        &mut r,
                    )
                    .unwrap();
                    assert_eq!(sb.batch.as_slice(), grp.as_ref(), "{} core drift", kind.name());
                    assert!(
                        sb.halo.iter().all(|h| !grp.contains(h)),
                        "{}: core node leaked into halo",
                        kind.name()
                    );
                    served.extend_from_slice(&sb.batch);
                }
                served.sort_unstable();
                let expect: Vec<u32> = {
                    let mut v: Vec<u32> =
                        clusters.iter().flat_map(|c| c.iter().copied()).collect();
                    v.sort_unstable();
                    v
                };
                assert_eq!(served, expect, "{} {mode:?}: epoch coverage broken", kind.name());
            }
        }
    }
}
