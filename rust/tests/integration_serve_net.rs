//! Integration: the networked serve front-end (ISSUE 8).
//!
//! The acceptance bar:
//!   * responses over loopback TCP are **byte-identical** to the lines an
//!     in-process `predict` would produce — the transport adds nothing and
//!     loses nothing;
//!   * requests from interleaved connections route back to their own
//!     connection and share micro-batches across streams;
//!   * `{"op":"shutdown"}` and SIGINT/SIGTERM all end in a graceful drain
//!     (queued input answered, final status line emitted) on both the TCP
//!     and stdin transports;
//!   * malformed node ids get per-request error lines instead of silently
//!     saturated/truncated predictions.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use lmc::config::RunConfig;
use lmc::graph::DatasetId;
use lmc::serve::net::{self, read_frame, write_frame, Event};
use lmc::serve::{BatchPolicy, LoopStats, ServeEngine, ServeLoop, ServeMode, Sink};
use lmc::util::json::Json;

fn engine(tile: usize) -> Arc<ServeEngine> {
    let cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        seed: 3,
        serve_mode: ServeMode::Exact,
        serve_max_batch: tile,
        ..Default::default()
    };
    Arc::new(ServeEngine::from_config(&cfg, None).unwrap())
}

fn start_server(
    eng: Arc<ServeEngine>,
    policy: BatchPolicy,
) -> (SocketAddr, thread::JoinHandle<LoopStats>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = thread::spawn(move || net::serve_tcp(eng, policy, listener, || None).unwrap());
    (addr, h)
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    s
}

fn send_req(s: &mut TcpStream, id: u64, nodes: &[u32]) {
    let csv = nodes.iter().map(|u| u.to_string()).collect::<Vec<_>>().join(",");
    write_frame(s, &format!("{{\"id\":{id},\"nodes\":[{csv}]}}")).unwrap();
}

#[test]
fn networked_exact_responses_are_bit_identical_to_in_process_predict() {
    let eng = engine(48);
    let local = Arc::clone(&eng);
    let (addr, server) = start_server(eng, BatchPolicy { max_nodes: 64, max_wait: 2 });
    let mut c = connect(addr);
    let requests: Vec<(u64, Vec<u32>)> =
        vec![(7, vec![0, 5, 5, 3]), (8, (0..40).collect()), (9, vec![11])];
    for (id, nodes) in &requests {
        send_req(&mut c, *id, nodes);
    }
    let mut got: BTreeMap<u64, String> = BTreeMap::new();
    for _ in 0..requests.len() {
        let line = read_frame(&mut c).unwrap().expect("response frame");
        let id = Json::parse(&line).unwrap().get("id").and_then(Json::as_usize).unwrap() as u64;
        got.insert(id, line);
    }
    for (id, nodes) in &requests {
        // byte-for-byte equality with the response line an in-process
        // predict would format for the same request
        let preds = local.predict(nodes).unwrap();
        assert_eq!(got[id], net::response_line(*id, &preds), "request {id}");
    }
    write_frame(&mut c, "{\"op\":\"shutdown\"}").unwrap();
    let stats = server.join().unwrap();
    assert_eq!(stats.reason, "op");
    assert_eq!((stats.requests, stats.served), (3, 4 + 40 + 1));
}

#[test]
fn interleaved_connections_route_responses_home_and_batch_across_streams() {
    let eng = engine(64);
    // the size threshold can only be crossed by pooling requests from BOTH
    // connections; the latency deadline is effectively infinite
    let (addr, server) = start_server(eng, BatchPolicy { max_nodes: 6, max_wait: 600_000 });
    let mut a = connect(addr);
    let mut b = connect(addr);
    for i in 0..3u32 {
        send_req(&mut a, (10 + 2 * i) as u64, &[i]);
        send_req(&mut b, (11 + 2 * i) as u64, &[10 + i]);
    }
    let drain = |s: &mut TcpStream| -> Vec<(u64, u32)> {
        (0..3)
            .map(|_| {
                let line = read_frame(s).unwrap().expect("response frame");
                let v = Json::parse(&line).unwrap();
                (
                    v.get("id").and_then(Json::as_usize).unwrap() as u64,
                    v.path("predictions.0.node").and_then(Json::as_usize).unwrap() as u32,
                )
            })
            .collect()
    };
    let mut got_a = drain(&mut a);
    let mut got_b = drain(&mut b);
    got_a.sort_unstable();
    got_b.sort_unstable();
    // every response landed on the connection its request arrived on,
    // carrying the node that request asked for
    assert_eq!(got_a, vec![(10, 0), (12, 1), (14, 2)]);
    assert_eq!(got_b, vec![(11, 10), (13, 11), (15, 12)]);
    write_frame(&mut a, "{\"op\":\"shutdown\"}").unwrap();
    // the drain broadcast reaches every open connection, not just the one
    // that asked for shutdown
    for s in [&mut a, &mut b] {
        let line = read_frame(s).unwrap().expect("broadcast shutdown frame");
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("op").and_then(Json::as_str), Some("shutdown"));
        assert_eq!(v.get("requests").and_then(Json::as_usize), Some(6));
    }
    let stats = server.join().unwrap();
    assert_eq!((stats.requests, stats.served), (6, 6));
    assert!(
        stats.batches < stats.requests,
        "6 single-node requests across 2 streams must share batches, got {} batches",
        stats.batches
    );
}

#[test]
fn serve_loop_answers_bad_ids_with_errors_and_drains_on_shutdown_op() {
    let eng = engine(64);
    let (tx, rx) = mpsc::channel::<Event>();
    let (out_tx, out_rx) = mpsc::channel::<String>();
    let sink = Sink::Chan(out_tx);
    for line in [
        "{\"id\":1,\"nodes\":[2]}",  // valid: queued behind the huge thresholds
        "{\"id\":2,\"nodes\":[-1]}", // used to saturate to node 0
        "[3.7]",                     // used to truncate to node 3
        "{\"op\":\"shutdown\"}",
    ] {
        tx.send(Event { sink: sink.clone(), line: line.to_string() }).unwrap();
    }
    let stats =
        ServeLoop::new(eng, BatchPolicy { max_nodes: 1000, max_wait: 600_000 }).run(&rx, || None);
    assert_eq!(stats.reason, "op");
    // the valid request was answered during the drain, not dropped; the
    // malformed ones never reached the engine
    assert_eq!((stats.requests, stats.served, stats.batches), (1, 1, 1));
    let lines: Vec<String> = out_rx.try_iter().collect();
    assert_eq!(lines.len(), 3, "{lines:?}");
    let err2 = Json::parse(&lines[0]).unwrap();
    assert_eq!(err2.get("id").and_then(Json::as_usize), Some(2), "error keeps the request id");
    assert!(err2.get("error").and_then(Json::as_str).unwrap().contains("out of u32 range"));
    let err3 = Json::parse(&lines[1]).unwrap();
    assert!(err3.get("id").is_none(), "bare arrays carry no id");
    assert!(err3.get("error").and_then(Json::as_str).unwrap().contains("not an integer"));
    let resp = Json::parse(&lines[2]).unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_usize), Some(1));
    assert_eq!(resp.path("predictions.0.node").and_then(Json::as_usize), Some(2));
}

#[test]
fn serve_loop_signal_stop_still_drains_queued_input() {
    let eng = engine(64);
    let (tx, rx) = mpsc::channel::<Event>();
    let (out_tx, out_rx) = mpsc::channel::<String>();
    tx.send(Event { sink: Sink::Chan(out_tx), line: "{\"id\":5,\"nodes\":[1,2]}".into() })
        .unwrap();
    // should_stop fires before the event is ever received: the drain path
    // must still parse and answer it — the SIGTERM/SIGINT semantics
    let stats = ServeLoop::new(eng, BatchPolicy { max_nodes: 1000, max_wait: 600_000 })
        .run(&rx, || Some("sigterm"));
    assert_eq!(stats.reason, "sigterm");
    assert_eq!((stats.requests, stats.served), (1, 2));
    let lines: Vec<String> = out_rx.try_iter().collect();
    assert_eq!(lines.len(), 1);
    assert_eq!(Json::parse(&lines[0]).unwrap().get("id").and_then(Json::as_usize), Some(5));
}

fn serve_cmd() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_lmc"));
    c.args(["serve", "--dataset", "cora-sim", "--arch", "gcn", "--seed", "3"]);
    c.args(["--serve-max-wait-ms", "5"]);
    c.stdin(Stdio::piped()).stdout(Stdio::piped()).stderr(Stdio::null());
    c
}

#[test]
fn serve_binary_stdin_transport_drains_on_shutdown_op() {
    let mut child = serve_cmd().spawn().unwrap();
    let mut stdin = child.stdin.take().unwrap();
    writeln!(stdin, "{{\"id\":3,\"nodes\":[0,1]}}").unwrap();
    writeln!(stdin, "{{\"op\":\"shutdown\"}}").unwrap();
    stdin.flush().unwrap();
    // stdin stays open: the exit below must come from the op, not EOF
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8(out.stdout).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    let resp = Json::parse(lines[0]).unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_usize), Some(3));
    assert_eq!(resp.path("predictions.0.node").and_then(Json::as_usize), Some(0));
    let down = Json::parse(lines[1]).unwrap();
    assert_eq!(down.get("op").and_then(Json::as_str), Some("shutdown"));
    assert_eq!(down.get("reason").and_then(Json::as_str), Some("op"));
    assert_eq!(down.get("served").and_then(Json::as_usize), Some(2));
    drop(stdin);
}

#[cfg(unix)]
#[test]
fn serve_binary_drains_on_sigint() {
    use std::io::{BufRead, BufReader, Read};
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGINT: i32 = 2;
    let mut child = serve_cmd().spawn().unwrap();
    let mut stdin = child.stdin.take().unwrap();
    writeln!(stdin, "[4]").unwrap();
    stdin.flush().unwrap();
    let mut out = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    out.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert!(resp.get("predictions").is_some(), "first line should answer the request: {line}");
    // Ctrl-C: the handler records the signal, the loop drains and exits 0
    // instead of dying mid-service
    assert_eq!(unsafe { kill(child.id() as i32, SIGINT) }, 0);
    let mut rest = String::new();
    out.read_to_string(&mut rest).unwrap();
    let last = rest.lines().last().expect("shutdown status line");
    let down = Json::parse(last).unwrap();
    assert_eq!(down.get("op").and_then(Json::as_str), Some("shutdown"));
    assert_eq!(down.get("reason").and_then(Json::as_str), Some("sigint"));
    assert!(child.wait().unwrap().success());
    drop(stdin);
}
