//! Integration: the halo-sampler zoo and the per-step gradient scale.
//!
//! Three contracts from the sampler-zoo PR:
//!   1. The no-subsampling path is bit-identical to pre-PR behaviour —
//!      `halo_sampler = none` (any `halo_keep`) and any policy at keep
//!      fraction 1.0 are inert passthroughs.
//!   2. Every subsampling policy trains to finite losses and metrics
//!      while actually dropping halo nodes (the rescale keeps the
//!      aggregation unbiased; `proptest_invariants` pins the expectation).
//!   3. The Eq. 14-15 gradient scale is per-step: the ragged last
//!      stochastic chunk gets b/|chunk|, so the epoch-summed mini-batch
//!      gradient matches the full-batch gradient on a zero-cut graph —
//!      where the constant b/c scale is measurably biased.

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::params::grad_rel_err;
use lmc::coordinator::{grad_check, Method, Trainer};
use lmc::graph::{disjoint_union, sbm, DatasetId, SbmSpec};
use lmc::runtime::Tensor;
use lmc::sampler::HaloSamplerKind;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new())
}

fn cfg(method: Method, epochs: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method,
        epochs,
        eval_every: epochs,
        seed: 3,
        ..Default::default()
    }
}

fn param_bits(t: &Trainer) -> Vec<u32> {
    let mut bits = Vec::new();
    for p in &t.params.tensors {
        bits.extend(p.data.iter().map(|x| x.to_bits()));
    }
    bits
}

/// Contract 1: `none` ignores `halo_keep`, and any policy at keep 1.0 is
/// a passthrough — all three trainers must end bit-identical to the
/// default configuration after full multi-epoch runs (same RNG stream:
/// passthrough builds consume nothing from the per-batch forks).
#[test]
fn no_subsampling_paths_are_bit_identical() {
    let mut base = Trainer::new(exec(), cfg(Method::Lmc, 3)).unwrap();
    base.run().unwrap();
    let want = param_bits(&base);

    // `none` with a different keep fraction: the knob must be inert.
    let mut inert = cfg(Method::Lmc, 3);
    inert.halo_keep = 0.25;
    let mut t = Trainer::new(exec(), inert).unwrap();
    t.run().unwrap();
    assert_eq!(param_bits(&t), want, "halo_keep must be inert under sampler none");

    // Every policy at frac 1.0 keeps the whole halo and skips the RNG.
    for kind in [
        HaloSamplerKind::Uniform,
        HaloSamplerKind::Labor,
        HaloSamplerKind::Importance,
    ] {
        let mut passthrough = cfg(Method::Lmc, 3);
        passthrough.halo_sampler = kind;
        passthrough.halo_keep = 1.0;
        let mut t = Trainer::new(exec(), passthrough).unwrap();
        t.run().unwrap();
        assert_eq!(
            param_bits(&t),
            want,
            "{} at keep 1.0 must be a bit-identical passthrough",
            kind.name()
        );
    }
}

/// Contract 2: each subsampling policy drops halo nodes yet still trains —
/// finite losses, finite accuracies, and a nonzero drop count (CoraSim's
/// partition cut guarantees halos exist to subsample).
#[test]
fn each_sampler_trains_finite_while_dropping_halo() {
    for kind in [
        HaloSamplerKind::Uniform,
        HaloSamplerKind::Labor,
        HaloSamplerKind::Importance,
    ] {
        let mut c = cfg(Method::Lmc, 2);
        c.halo_sampler = kind;
        c.halo_keep = 0.5;
        let mut t = Trainer::new(exec(), c).unwrap();
        let mut dropped = 0usize;
        for _ in 0..2 {
            let stats = t.train_epoch().unwrap();
            assert!(stats.loss_mean.is_finite(), "{}: non-finite epoch loss", kind.name());
            dropped += stats.dropped_halo;
        }
        assert!(dropped > 0, "{}: keep 0.5 never dropped a halo node", kind.name());
        let ev = t.evaluate().unwrap();
        assert!(ev.train_loss.is_finite(), "{}: non-finite eval loss", kind.name());
        for (name, acc) in [("train", ev.train_acc), ("val", ev.val_acc), ("test", ev.test_acc)] {
            assert!(
                (0.0..=1.0).contains(&acc),
                "{}: {name} accuracy {acc} out of range",
                kind.name()
            );
        }
    }
}

/// Contract 3a (linearity): the backend applies `grad_scale` as a pure
/// multiplier, so on the ragged last stochastic chunk the per-step
/// gradients must equal the constant-scale gradients times
/// `grad_scale_at / grad_scale`. CoraSim has 8 parts; 3 clusters per
/// batch makes chunks of 3, 3, 2 — the last step's factor is 8/2, not
/// the constant 8/3. Unbounded native buckets keep both builds
/// deterministic (no RNG consumed), so the two calls see the same
/// subgraph.
#[test]
fn ragged_last_chunk_uses_per_step_scale() {
    let mut c = cfg(Method::Lmc, 1);
    c.clusters_per_batch = 3;
    let mut t = Trainer::new(exec(), c).unwrap();
    assert_eq!(t.clusters.len(), 8, "cora-sim should default to 8 parts");

    let batches = t.batcher.clone().epoch_batches();
    assert_eq!(batches.len(), 3);
    let last = batches.len() - 1;
    let gs_const = t.batcher.grad_scale();
    let gs_at = t.batcher.grad_scale_at(last);
    assert!((gs_const - 8.0 / 3.0).abs() < 1e-6);
    assert!((gs_at - 4.0).abs() < 1e-6, "ragged chunk of 2 clusters wants 8/2");

    let (_, g_const) = t.compute_minibatch_grads(&batches[last], None, false).unwrap();
    let (_, g_at) = t.compute_minibatch_grads_at(last, &batches[last], None, false).unwrap();
    let ratio = gs_at / gs_const;
    let scaled: Vec<Tensor> = g_const
        .iter()
        .map(|g| Tensor::from_vec(&g.shape, g.data.iter().map(|x| x * ratio).collect()))
        .collect();
    let err = grad_rel_err(&g_at, &scaled);
    assert!(err < 1e-5, "per-step grads deviate from scaled constant grads: {err}");

    // Non-ragged steps keep the constant factor.
    assert!((t.batcher.grad_scale_at(0) - gs_const).abs() < 1e-6);
    assert!((t.batcher.grad_scale_at(1) - gs_const).abs() < 1e-6);
}

/// Contract 3b (end-to-end): on a graph whose partition cut is zero the
/// CLUSTER-GCN estimator is exact per batch, so the epoch-summed
/// mini-batch gradient — each batch divided by its own per-step weight —
/// must reproduce the full-batch gradient. The same sum weighted by the
/// constant b/c must not: it triple-counts the ragged chunk. Seven
/// disjoint SBM components with 3 clusters per batch give chunks of
/// 3, 3, 1.
///
/// The partitioner is not *guaranteed* to recover components, so the
/// bias assertions run only when the realized cut is zero (asserted via
/// an explicit edge scan); the precondition has held for the pinned seed.
#[test]
fn epoch_summed_gradient_matches_full_batch_on_zero_cut_graph() {
    // Dims must match CoraSim's planetoid profile (d_x = 48, 7 classes).
    let comps: Vec<_> = (0..7)
        .map(|i| {
            sbm(&SbmSpec {
                n: 60,
                n_class: 7,
                d_x: 48,
                avg_deg_in: 2.5,
                avg_deg_out: 1.5,
                signal: 0.2,
                train_frac: 1.0,
                val_frac: 0.0,
                seed: 1000 + i,
                mu_seed: Some(1000),
            })
        })
        .collect();
    let raw = disjoint_union(comps, &[0; 7]);

    let mut c = cfg(Method::Cluster, 1);
    c.parts = 7;
    c.clusters_per_batch = 3;
    let mut t = Trainer::from_parent_graph(exec(), c, raw).unwrap();
    assert_eq!(t.clusters.len(), 7);

    // Verify the zero-cut precondition on the trainer's (relabeled) graph.
    let n = t.graph.n();
    let mut cluster_of = vec![u32::MAX; n];
    for (ci, cl) in t.clusters.iter().enumerate() {
        for &u in cl {
            cluster_of[u as usize] = ci as u32;
        }
    }
    let mut cut = 0usize;
    for u in 0..n {
        for &v in t.graph.csr.neighbors(u) {
            if cluster_of[u] != cluster_of[v as usize] {
                cut += 1;
            }
        }
    }
    if cut != 0 {
        eprintln!("partitioner split a component (cut {cut}); skipping bias pin");
        return;
    }

    // Per-step weights: the epoch sum reproduces the full-batch gradient.
    let bias = grad_check::measure_bias(&mut t).unwrap();
    assert!(bias < 2e-2, "per-step-weighted epoch sum is biased: {bias}");

    // Constant b/c weights (the pre-fix behaviour) overweight the ragged
    // single-cluster chunk by 3x and land far from the oracle.
    let oracle = t.exec.full_grad(t.graph.as_ref(), &t.params, &t.model).unwrap();
    let gs_const = t.batcher.grad_scale() as f64;
    let batches = t.batcher.clone().epoch_batches();
    assert_eq!(batches.len(), 3, "7 clusters / 3 per batch");
    let mut sum: Vec<Vec<f64>> = Vec::new();
    for (i, batch) in batches.iter().enumerate() {
        let (_, grads) = t.compute_minibatch_grads_at(i, batch, None, false).unwrap();
        if sum.is_empty() {
            sum = grads.iter().map(|g| vec![0f64; g.data.len()]).collect();
        }
        for (acc, g) in sum.iter_mut().zip(&grads) {
            for (a, x) in acc.iter_mut().zip(&g.data) {
                *a += *x as f64 / gs_const;
            }
        }
    }
    let biased: Vec<Tensor> = sum
        .iter()
        .zip(&oracle.grads)
        .map(|(acc, o)| Tensor::from_vec(&o.shape, acc.iter().map(|x| *x as f32).collect()))
        .collect();
    let const_bias = grad_rel_err(&biased, &oracle.grads);
    assert!(
        const_bias > 5e-2,
        "constant-scale sum should be visibly biased on the ragged schedule, got {const_bias}"
    );
    assert!(
        bias < const_bias / 2.0,
        "per-step weighting ({bias}) should beat constant weighting ({const_bias})"
    );
}
