//! Integration: the batched inference service end-to-end against the
//! exact full-graph oracle, on the default native backend.
//!
//! The acceptance bar (ISSUE 5):
//!   * exact-tile serve path is **bit-identical** to the full-graph exact
//!     oracle;
//!   * the cached-history path tracks the oracle within 1e-4 with a warm
//!     history;
//!   * a param-update → history-invalidation → re-predict sequence is
//!     deterministic across two runs.

use std::sync::Arc;

use lmc::backend::NativeExecutor;
use lmc::config::RunConfig;
use lmc::coordinator::{Params, Trainer};
use lmc::graph::DatasetId;
use lmc::serve::{
    BatchPolicy, MicroBatcher, Prediction, ServeEngine, ServeMode, ServeRequest,
};
use lmc::util::rng::Rng;

fn engine(arch: &str, mode: ServeMode, tile: usize) -> ServeEngine {
    let cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: arch.into(),
        seed: 3,
        serve_mode: mode,
        serve_max_batch: tile,
        ..Default::default()
    };
    ServeEngine::from_config(&cfg, None).unwrap()
}

fn logits_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: width mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{ctx}: logit {i}: {g} vs {w}"
        );
    }
}

#[test]
fn exact_tile_serve_is_bit_identical_to_full_oracle() {
    for arch in ["gcn", "gcnii"] {
        // a small tile knob forces the request through several tiles
        let eng = engine(arch, ServeMode::Exact, 48);
        let oracle = eng.oracle_logits().unwrap();
        let n = eng.graph().n();
        let c = oracle.len() / n;
        let nodes: Vec<u32> = (0..n as u32).step_by(7).collect();
        let preds = eng.predict(&nodes).unwrap();
        assert_eq!(preds.len(), nodes.len());
        for p in &preds {
            let u = p.node as usize;
            assert_eq!(
                p.logits,
                &oracle[u * c..(u + 1) * c],
                "{arch}: node {u} exact-tile logits differ from the oracle"
            );
        }
    }
}

#[test]
fn cached_history_path_tracks_oracle_within_1e4() {
    for arch in ["gcn", "gcnii"] {
        let mut eng = engine(arch, ServeMode::Cached, 64);
        eng.refresh_history().unwrap();
        assert!(eng.is_warm());
        let oracle = eng.oracle_logits().unwrap();
        let n = eng.graph().n();
        let c = oracle.len() / n;
        let nodes: Vec<u32> = (0..n as u32).step_by(3).collect();
        let preds = eng.predict(&nodes).unwrap();
        for p in &preds {
            let u = p.node as usize;
            logits_close(
                &p.logits,
                &oracle[u * c..(u + 1) * c],
                1e-4,
                &format!("{arch}: node {u} cached vs oracle"),
            );
        }
    }
}

#[test]
fn cached_path_refuses_stale_history_and_exact_path_does_not() {
    let mut eng = engine("gcn", ServeMode::Cached, 64);
    // never warmed: the cached path must refuse rather than serve zeros
    let err = eng.predict(&[0, 1, 2]).unwrap_err();
    assert!(err.to_string().contains("stale"), "{err}");
    // the exact path needs no history at all
    assert_eq!(eng.predict_in_mode(&[0, 1, 2], ServeMode::Exact).unwrap().len(), 3);
    eng.refresh_history().unwrap();
    assert!(eng.predict(&[0, 1, 2]).is_ok());
    // a params swap invalidates again
    let fresh = Params::init(&eng.model().arch, &mut Rng::new(0xFEED));
    eng.set_params(fresh).unwrap();
    assert!(!eng.is_warm());
    assert!(eng.predict(&[0, 1, 2]).is_err());
}

#[test]
fn param_update_then_repredict_is_deterministic() {
    // The whole update → invalidate → refresh → re-predict sequence must
    // replay bit-identically in a fresh engine.
    let run = || {
        let mut eng = engine("gcn", ServeMode::Cached, 64);
        eng.refresh_history().unwrap();
        let nodes: Vec<u32> = (0..160u32).collect();
        let before: Vec<Prediction> = eng.predict(&nodes).unwrap();
        let v0 = eng.params_version();
        let next = Params::init(&eng.model().arch, &mut Rng::new(0xBEEF));
        eng.set_params(next).unwrap();
        assert_eq!(eng.params_version(), v0 + 1);
        eng.refresh_history().unwrap();
        let after: Vec<Prediction> = eng.predict(&nodes).unwrap();
        (before, after)
    };
    let (b1, a1) = run();
    let (b2, a2) = run();
    assert_eq!(b1, b2, "pre-update predictions not reproducible");
    assert_eq!(a1, a2, "post-update predictions not reproducible");
    // the parameter swap is actually visible in the served logits
    assert_ne!(
        b1.iter().map(|p| p.logits.clone()).collect::<Vec<_>>(),
        a1.iter().map(|p| p.logits.clone()).collect::<Vec<_>>(),
        "updated params served identical logits"
    );
}

#[test]
fn trained_params_roundtrip_through_disk_into_the_engine() {
    // train a couple of epochs, save, reload bitwise, serve with the
    // loaded params: cached path still tracks that engine's own oracle.
    let cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        epochs: 2,
        eval_every: usize::MAX,
        seed: 5,
        ..Default::default()
    };
    let mut t = Trainer::new(Arc::new(NativeExecutor::new()), cfg).unwrap();
    for _ in 0..2 {
        t.train_epoch().unwrap();
    }
    let path = std::env::temp_dir()
        .join(format!("lmc_serve_roundtrip_{}.params", std::process::id()));
    t.params.save(&path).unwrap();
    let loaded = Params::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for (a, b) in t.params.tensors.iter().zip(&loaded.tensors) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data, "save/load round-trip not bitwise");
    }

    let serve_cfg = RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        seed: 5,
        serve_max_batch: 96,
        ..Default::default()
    };
    let mut eng = ServeEngine::from_config(&serve_cfg, Some(loaded)).unwrap();
    eng.refresh_history().unwrap();
    let oracle = eng.oracle_logits().unwrap();
    let n = eng.graph().n();
    let c = oracle.len() / n;
    let nodes: Vec<u32> = (0..n as u32).step_by(5).collect();
    for p in &eng.predict(&nodes).unwrap() {
        let u = p.node as usize;
        logits_close(
            &p.logits,
            &oracle[u * c..(u + 1) * c],
            1e-4,
            &format!("trained-params node {u}"),
        );
    }
}

#[test]
fn micro_batched_requests_route_back_per_request() {
    let eng = engine("gcn", ServeMode::Exact, 32);
    let mut mb = MicroBatcher::new(BatchPolicy { max_nodes: 8, max_wait: 10 });
    assert!(mb
        .push(ServeRequest { id: 1, nodes: vec![5, 3, 5] }, 0)
        .is_none());
    // 3 + 6 = 9 >= 8 queued nodes: size flush
    let batch = mb
        .push(ServeRequest { id: 2, nodes: vec![1, 2, 3, 4, 9, 10] }, 1)
        .expect("size flush");
    let answers = eng.answer(&batch).unwrap();
    assert_eq!(answers.len(), 2);
    let (id1, preds1) = &answers[0];
    let (id2, preds2) = &answers[1];
    assert_eq!((*id1, *id2), (1, 2));
    // request order and duplicates are preserved per request
    assert_eq!(preds1.iter().map(|p| p.node).collect::<Vec<_>>(), vec![5, 3, 5]);
    assert_eq!(
        preds2.iter().map(|p| p.node).collect::<Vec<_>>(),
        vec![1, 2, 3, 4, 9, 10]
    );
    // a duplicated node is served the same logits
    assert_eq!(preds1[0].logits, preds1[2].logits);
    // shared node across requests agrees too
    assert_eq!(preds1[1].logits, preds2[2].logits);

    // latency flush path: a lone small request drains on deadline
    assert!(mb.push(ServeRequest { id: 3, nodes: vec![0] }, 20).is_none());
    assert!(mb.poll(29).is_none());
    let late = mb.poll(30).expect("deadline flush");
    assert_eq!(eng.answer(&late).unwrap()[0].1.len(), 1);
}

#[test]
fn tile_workspace_pool_is_stable_across_serial_predicts() {
    // The exact path checks an epoch-stamped visited/scatter workspace out
    // of a pool instead of allocating O(n) buffers per tile (ISSUE 8). A
    // serial caller must miss the pool at most once — ever — and reused
    // buffers must not perturb the served logits.
    let eng = engine("gcn", ServeMode::Exact, 48);
    let nodes: Vec<u32> = (0..eng.graph().n() as u32).step_by(11).collect();
    let first = eng.predict(&nodes).unwrap();
    let warm = eng.tile_ws_misses();
    assert!(warm <= 1, "a serial caller needs at most one workspace, saw {warm} misses");
    for _ in 0..16 {
        assert_eq!(
            eng.predict(&nodes).unwrap(),
            first,
            "workspace reuse changed served predictions"
        );
    }
    assert_eq!(
        eng.tile_ws_misses(),
        warm,
        "repeat predicts must reuse the pooled workspace, not allocate fresh ones"
    );
}

#[test]
fn serve_rejects_out_of_range_nodes() {
    let eng = engine("gcn", ServeMode::Exact, 32);
    let n = eng.graph().n() as u32;
    let err = eng.predict(&[0, n]).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}
