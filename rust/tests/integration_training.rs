//! Integration: end-to-end training behaviour per method on cora-sim,
//! running on the default native backend — no AOT artifacts required.

use std::sync::Arc;

use lmc::backend::{Executor, NativeExecutor};
use lmc::config::RunConfig;
use lmc::coordinator::{grad_check, Method, Trainer};
use lmc::graph::DatasetId;

fn exec() -> Arc<dyn Executor> {
    Arc::new(NativeExecutor::new())
}

fn cfg(method: Method, epochs: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetId::CoraSim,
        arch: "gcn".into(),
        method,
        epochs,
        eval_every: epochs,
        seed: 1,
        ..Default::default()
    }
}

#[test]
fn every_method_trains_and_learns() {
    let exec = exec();
    for method in [Method::Lmc, Method::Gas, Method::Fm, Method::Cluster] {
        let mut t = Trainer::new(exec.clone(), cfg(method, 6)).unwrap();
        let m = t.run().unwrap();
        let first = m.records.first().unwrap().train_loss;
        let last = m.records.last().unwrap().train_loss;
        assert!(
            last < first * 0.7,
            "{}: loss did not drop ({first} -> {last})",
            method.name()
        );
        let test = m.final_test().unwrap();
        assert!(test > 0.4, "{}: test acc {test} not above chance", method.name());
    }
}

#[test]
fn gd_oracle_trains() {
    let mut t = Trainer::new(exec(), cfg(Method::Gd, 8)).unwrap();
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first, "GD loss {first} -> {last}");
}

#[test]
fn gcnii_trains_too() {
    let mut c = cfg(Method::Lmc, 5);
    c.arch = "gcnii".into();
    let mut t = Trainer::new(exec(), c).unwrap();
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first, "GCNII loss {first} -> {last}");
}

#[test]
fn lmc_gradient_bias_beats_gas_and_cluster() {
    // The paper's core claim (Fig. 3 / Theorem 2): LMC's compensations
    // shrink the mini-batch gradient *bias*. Controlled comparison: one
    // LMC-trained state (params + histories), then the partition-summed
    // bias measured with each method's policy toggled — same parameter
    // point, same histories, same batches, so only the compensation
    // differs. Theorem 2's regime needs moderate staleness, hence the
    // reduced learning rate.
    let mut c = cfg(Method::Lmc, 3);
    c.dataset = DatasetId::ArxivSim;
    c.lr = 3e-3;
    let mut t = Trainer::new(exec(), c).unwrap();
    for _ in 0..3 {
        t.train_epoch().unwrap();
    }
    let mut errs = std::collections::HashMap::new();
    for method in [Method::Lmc, Method::Gas, Method::Cluster] {
        t.set_method(method).unwrap();
        errs.insert(method.name(), grad_check::measure_bias(&mut t).unwrap());
    }
    let (lmc, gas, cluster) = (errs["LMC"], errs["GAS"], errs["CLUSTER"]);
    assert!(lmc < gas, "LMC {lmc} !< GAS {gas}");
    assert!(lmc < cluster, "LMC {lmc} !< CLUSTER {cluster}");
}

#[test]
fn history_staleness_decreases_with_more_frequent_visits() {
    let exec = exec();
    // larger batches -> every node visited sooner -> lower mean staleness
    let mut small = Trainer::new(exec.clone(), {
        let mut c = cfg(Method::Lmc, 2);
        c.clusters_per_batch = 1;
        c
    })
    .unwrap();
    small.run().unwrap();
    let mut big = Trainer::new(exec, {
        let mut c = cfg(Method::Lmc, 2);
        c.clusters_per_batch = 4;
        c
    })
    .unwrap();
    big.run().unwrap();
    let (bs, ss) = (big.history.mean_staleness(), small.history.mean_staleness());
    assert!(bs <= ss + 1e-9, "big-batch staleness {bs} > small-batch {ss}");
}

#[test]
fn fixed_batches_mode_runs() {
    let mut c = cfg(Method::Lmc, 3);
    c.batcher_mode = lmc::sampler::BatcherMode::Fixed;
    let mut t = Trainer::new(exec(), c).unwrap();
    let m = t.run().unwrap();
    assert_eq!(m.records.len(), 3);
    // with unbounded buckets the Fixed schedule is cacheable, and after a
    // full epoch the cache is sealed
    assert!(t.sg_cache.enabled());
    assert!(!t.sg_cache.is_empty());
}

#[test]
fn fixed_mode_subgraph_cache_matches_uncached() {
    // The cache must be a pure memoization: training with it on and off
    // produces bit-identical parameters (history gathers stay per-step).
    let run = |cache: bool, pipeline: bool| {
        let mut c = cfg(Method::Lmc, 3);
        c.batcher_mode = lmc::sampler::BatcherMode::Fixed;
        c.subgraph_cache = cache;
        c.pipeline = pipeline;
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        for _ in 0..3 {
            t.train_epoch().unwrap();
        }
        t.params.tensors.clone()
    };
    let cached = run(true, false);
    let uncached = run(false, false);
    let cached_pipelined = run(true, true);
    assert_eq!(cached.len(), uncached.len());
    for ((a, b), c) in cached.iter().zip(&uncached).zip(&cached_pipelined) {
        assert_eq!(a.data, b.data, "cache changed training results");
        assert_eq!(a.data, c.data, "cache + pipeline diverged");
    }
}

#[test]
fn stochastic_mode_cache_flag_is_inert() {
    // SubgraphCache fallback path #1: Stochastic batches reshuffle every
    // epoch, so the cache must stay disabled and the per-step rebuilds must
    // match the cache-off configuration bit-for-bit.
    let run = |cache_flag: bool| {
        let mut c = cfg(Method::Lmc, 3);
        c.batcher_mode = lmc::sampler::BatcherMode::Stochastic;
        c.subgraph_cache = cache_flag;
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        for _ in 0..3 {
            t.train_epoch().unwrap();
        }
        assert!(t.sg_cache.is_empty(), "Stochastic mode must never cache");
        t.params.tensors.clone()
    };
    let on = run(true);
    let off = run(false);
    for (a, b) in on.iter().zip(&off) {
        assert_eq!(a.data, b.data, "cache flag changed Stochastic-mode results");
    }
}

#[test]
fn capped_buckets_fall_back_to_per_step_rebuilds() {
    // SubgraphCache fallback path #2: a bucket cap subsamples the halo
    // through the per-batch RNG stream, so even Fixed mode must not cache
    // (the applicability gate says so), and identically-seeded capped runs
    // still rebuild deterministically per step.
    use lmc::sampler::{BatcherMode, Buckets, SubgraphCache};
    let capped = Buckets(vec![(1024, 24)]);
    assert!(!SubgraphCache::applicable(true, BatcherMode::Fixed, &capped));
    assert!(SubgraphCache::applicable(true, BatcherMode::Fixed, &Buckets::unbounded()));
    let run = || {
        let mut c = cfg(Method::Lmc, 2);
        c.batcher_mode = BatcherMode::Fixed;
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        // impose the capped-bucket regime (the native backend itself always
        // requests unbounded buckets) and re-derive the cache gate the way
        // the constructor does
        t.buckets = Buckets(vec![(1024, 24)]);
        t.sg_cache = SubgraphCache::new(SubgraphCache::applicable(
            t.cfg.subgraph_cache,
            t.batcher.mode(),
            &t.buckets,
        ));
        assert!(!t.sg_cache.enabled());
        let mut dropped = 0usize;
        for _ in 0..2 {
            dropped += t.train_epoch().unwrap().dropped_halo;
        }
        assert!(t.sg_cache.is_empty(), "capped buckets must not cache");
        (t.params.tensors.clone(), dropped)
    };
    let (p1, d1) = run();
    let (p2, d2) = run();
    assert!(d1 > 0, "a 24-row halo cap should drop neighbors on cora-sim");
    assert_eq!(d1, d2, "halo subsampling not deterministic across runs");
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.data, b.data, "capped per-step rebuilds diverged");
    }
}

#[test]
fn stochastic_mode_never_caches() {
    let mut c = cfg(Method::Lmc, 2);
    c.batcher_mode = lmc::sampler::BatcherMode::Stochastic;
    let mut t = Trainer::new(exec(), c).unwrap();
    t.run().unwrap();
    assert!(!t.sg_cache.enabled());
    assert!(t.sg_cache.is_empty());
}

#[test]
fn workspace_steady_state_has_no_new_allocations() {
    // After warmup epochs the buffer pool and subgraph cache cover every
    // per-layer grab: further epochs must not heap-allocate step buffers.
    let mut c = cfg(Method::Lmc, 1);
    c.batcher_mode = lmc::sampler::BatcherMode::Fixed;
    let mut t = Trainer::new(exec(), c).unwrap();
    t.train_epoch().unwrap();
    t.train_epoch().unwrap();
    let warm = t.ws.lock().unwrap().misses();
    t.train_epoch().unwrap();
    t.train_epoch().unwrap();
    let steady = t.ws.lock().unwrap().misses();
    assert_eq!(warm, steady, "steady-state epochs still allocate step buffers");
    assert!(t.ws.lock().unwrap().grabs() > warm, "workspace not exercised");
}

#[test]
fn ppi_inductive_trains() {
    let mut c = cfg(Method::Lmc, 4);
    c.dataset = DatasetId::PpiSim;
    let mut t = Trainer::new(exec(), c).unwrap();
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first, "ppi loss {first} -> {last}");
    // inductive test graph accuracy above chance (12 classes)
    assert!(m.final_test().unwrap() > 1.5 / 12.0);
}

#[test]
fn pipeline_and_serial_paths_are_identical() {
    // Unified per-batch forked RNG streams: the prefetch pipeline must
    // sample the same halo subsets and produce bit-identical parameters.
    let run = |pipeline: bool| {
        let mut c = cfg(Method::Lmc, 3);
        c.pipeline = pipeline;
        c.eval_every = usize::MAX;
        let mut t = Trainer::new(exec(), c).unwrap();
        for _ in 0..3 {
            t.train_epoch().unwrap();
        }
        t.params.tensors.clone()
    };
    let serial = run(false);
    let pipelined = run(true);
    assert_eq!(serial.len(), pipelined.len());
    for (a, b) in serial.iter().zip(&pipelined) {
        assert_eq!(a.data, b.data, "pipeline diverged from serial path");
    }
}

#[test]
fn spider_variant_runs_and_learns() {
    let mut c = cfg(Method::LmcSpider, 4);
    c.spider_period = 3;
    let mut t = Trainer::new(exec(), c).unwrap();
    let m = t.run().unwrap();
    let first = m.records.first().unwrap().train_loss;
    let last = m.records.last().unwrap().train_loss;
    assert!(last < first, "SPIDER loss {first} -> {last}");
}
