//! Compile-time stub of the XLA/PJRT binding crate.
//!
//! The `lmc` crate's `pjrt` feature needs an `xla` crate exposing the PJRT
//! C-API surface (client, compiled executable, literals). The real bindings
//! link against a PJRT plugin and cannot be vendored here, so this stub
//! provides the same API shape and fails at the first runtime entry point
//! (`PjRtClient::cpu`) with an actionable message. This keeps
//! `cargo check --features pjrt` working on machines with no XLA toolchain.
//!
//! To enable real PJRT execution, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual bindings (e.g. a local build of the
//! `xla` PJRT wrapper used to produce the AOT artifacts) — the API below
//! mirrors the subset the `lmc` crate calls, so no source changes are
//! needed.

use std::fmt;

/// Error type mirroring the real bindings' error (Display-able).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT unavailable: the `xla` dependency is the in-repo API stub \
         (rust/vendor/xla). Point Cargo.toml at the real PJRT bindings to \
         execute AOT artifacts, or use the default native backend."
            .to_string(),
    ))
}

/// Host literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_x: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub_unavailable()
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        stub_unavailable()
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        stub_unavailable()
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_unavailable()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_unavailable()
    }
}

/// PJRT client (stub; `cpu()` is the first call every path makes, so the
/// stub fails fast with a clear message).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub_unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_unavailable()
    }
}
